//! Loom model tests for the M:N runtime's concurrency primitives.
//!
//! Compiled (and meaningful) only under the loom cfg:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_runtime
//! ```
//!
//! The primitives themselves ([`StealQueue`], [`MailSlot`], [`EpochFloor`],
//! [`TimerService`]) build against `loom::sync` via the
//! `apibcd::util::sync` facade, so every interleaving explored here is an
//! interleaving of the *production* code, not a test replica. The fast CI
//! tier bounds exploration with `LOOM_MAX_PREEMPTIONS`; the weekly deep
//! tier runs unbounded. See EXPERIMENTS.md §Verification.
//!
//! Thread budget: loom models at most 4 threads (including the model's
//! main thread) — every scenario here spawns ≤ 2 and uses the main thread
//! as the third actor.
#![cfg(loom)]

use apibcd::engine::claim::{EpochFloor, MailSlot};
use apibcd::engine::timer::TimerService;
use apibcd::scenario::executor::StealQueue;
use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use loom::sync::Arc;
use loom::thread;

/// The satellite-1 window (`scheduled.store(false)` → inbox-recheck →
/// re-claim) against a concurrent delivery: in every interleaving the
/// message ends up claim-covered by exactly one run-queue entry — never
/// stranded in an unscheduled mailbox, never double-enqueued.
#[test]
fn release_recheck_never_strands_a_delivery() {
    loom::model(|| {
        let slot: Arc<MailSlot<u32>> = Arc::new(MailSlot::new());
        let q: Arc<StealQueue<usize>> = Arc::new(StealQueue::new(1));
        // A worker is mid-claim on agent 0 with an already-drained mailbox
        // (the state right before `run_claimed`'s release path).
        assert!(slot.try_claim());

        let s2 = Arc::clone(&slot);
        let q2 = Arc::clone(&q);
        let deliverer = thread::spawn(move || {
            if s2.deliver(7) {
                q2.push(0, 0);
            }
        });
        // The owner's release path (MailSlot::release = store(false),
        // recheck, swap re-claim).
        if slot.release() {
            q.push(0, 0);
        }
        deliverer.join().unwrap();

        let mut entries = 0;
        while q.try_pop(0).is_some() {
            entries += 1;
        }
        assert_eq!(entries, 1, "message must be covered by exactly one entry");
        assert!(slot.is_claimed(), "the covering entry carries the claim");
        assert_eq!(slot.take(), Some(7), "and the message is still there");
    });
}

/// Claim/steal interleaving with two workers racing two agents: the claim
/// bit admits at most one worker per agent at a time (single ownership —
/// the arena-row handoff invariant), queue entries never materialize
/// without a claim (no phantom wakeup), and no delivered message is lost:
/// everything is either served or swept after the drain barrier.
#[test]
fn claim_steal_close_single_ownership_no_lost_messages() {
    loom::model(|| {
        let slots: Arc<Vec<MailSlot<u32>>> =
            Arc::new((0..2).map(|_| MailSlot::new()).collect());
        let q: Arc<StealQueue<usize>> = Arc::new(StealQueue::new(2));
        let running: Arc<Vec<AtomicUsize>> =
            Arc::new((0..2).map(|_| AtomicUsize::new(0)).collect());
        let served = Arc::new(AtomicUsize::new(0));

        let mut workers = Vec::new();
        for w in 0..2usize {
            let slots = Arc::clone(&slots);
            let q = Arc::clone(&q);
            let running = Arc::clone(&running);
            let served = Arc::clone(&served);
            workers.push(thread::spawn(move || {
                while let Some(i) = q.pop(w) {
                    assert!(
                        slots[i].is_claimed(),
                        "phantom wakeup: queue entry without a claim"
                    );
                    let was = running[i].fetch_add(1, Ordering::SeqCst);
                    assert_eq!(was, 0, "two workers own agent {i} at once");
                    if slots[i].take().is_some() {
                        served.fetch_add(1, Ordering::SeqCst);
                    }
                    running[i].fetch_sub(1, Ordering::SeqCst);
                    if slots[i].has_mail() {
                        q.push(i, i);
                    } else if slots[i].release() {
                        q.push(i, i);
                    }
                }
            }));
        }

        // Main is the deliverer, then trips the drain barrier.
        for (m, dest) in [(1u32, 0usize), (2, 1)] {
            if slots[dest].deliver(m) {
                q.push(dest, dest);
            }
        }
        q.close();
        for h in workers {
            h.join().unwrap();
        }

        // Post-quiescence accounting: a close can strand entries in the
        // queue and messages in mailboxes — the owner sweep (as in the
        // runtimes' shutdown) must find exactly the unserved remainder.
        let _ = q.drain();
        let swept: usize = slots.iter().map(|s| s.sweep().len()).sum();
        assert_eq!(
            served.load(Ordering::SeqCst) + swept,
            2,
            "every delivered message is served or swept, exactly once"
        );
    });
}

/// `close()` is a reliable drain-and-park barrier: with workers parked or
/// parking on an empty-then-nonempty queue, close wakes everyone (loom
/// itself fails the model on any deadlocked schedule), and the one pushed
/// item is claimed at most once — by a worker or by the owner's sweep.
#[test]
fn stealqueue_close_wakes_every_parked_worker() {
    loom::model(|| {
        let q: Arc<StealQueue<u32>> = Arc::new(StealQueue::new(2));
        let mut workers = Vec::new();
        for w in 0..2usize {
            let q = Arc::clone(&q);
            workers.push(thread::spawn(move || q.pop(w)));
        }
        q.push(0, 9);
        q.close();
        let popped = workers
            .into_iter()
            .map(|h| h.join().unwrap())
            .filter(Option::is_some)
            .count();
        let swept = q.drain().len();
        assert_eq!(popped + swept, 1, "the item is claimed exactly once");
    });
}

/// Stop-flag vs in-flight token: the `run_claimed` stop skeleton (drain +
/// release in one inbox critical section) races a delivery and the stop
/// trip — in every interleaving the token is served, retired by the
/// drain, or swept by the owner; never lost, never double-counted.
#[test]
fn stop_drain_retires_every_in_flight_token() {
    loom::model(|| {
        let slot: Arc<MailSlot<u32>> = Arc::new(MailSlot::new());
        let q: Arc<StealQueue<usize>> = Arc::new(StealQueue::new(1));
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicUsize::new(0));
        let retired = Arc::new(AtomicUsize::new(0));

        let worker = {
            let slot = Arc::clone(&slot);
            let q = Arc::clone(&q);
            let stop = Arc::clone(&stop);
            let served = Arc::clone(&served);
            let retired = Arc::clone(&retired);
            thread::spawn(move || {
                while let Some(_i) = q.pop(0) {
                    if stop.load(Ordering::SeqCst) {
                        retired.fetch_add(slot.drain_and_release().len(), Ordering::SeqCst);
                        continue;
                    }
                    if slot.take().is_some() {
                        served.fetch_add(1, Ordering::SeqCst);
                    }
                    if slot.has_mail() {
                        q.push(0, 0);
                    } else if slot.release() {
                        q.push(0, 0);
                    }
                }
            })
        };
        let deliverer = {
            let slot = Arc::clone(&slot);
            let q = Arc::clone(&q);
            thread::spawn(move || {
                if slot.deliver(1) {
                    q.push(0, 0);
                }
            })
        };

        // Main trips the stop barrier, racing both.
        stop.store(true, Ordering::SeqCst);
        q.close();
        deliverer.join().unwrap();
        worker.join().unwrap();

        let _ = q.drain();
        let swept = slot.sweep().len();
        let total = served.load(Ordering::SeqCst) + retired.load(Ordering::SeqCst) + swept;
        assert_eq!(total, 1, "in-flight token: served, retired, or swept");
    });
}

/// `TimerWheel` deadline insertion racing the timekeeper's
/// park/advance/stop cycle: under loom the timekeeper has *no* timeout
/// backstop, so this model proves the notify protocol alone never loses a
/// wakeup (a lost one deadlocks the schedule and fails the model), and
/// the scheduled item is fired or drained — exactly once.
#[test]
fn timer_schedule_races_timekeeper_and_stop() {
    loom::model(|| {
        let svc: Arc<TimerService<u8>> = Arc::new(TimerService::new(1.0, 2));
        let fired = Arc::new(AtomicUsize::new(0));

        let timekeeper = {
            let svc = Arc::clone(&svc);
            let fired = Arc::clone(&fired);
            thread::spawn(move || {
                let mut due = Vec::new();
                while svc.next_batch(|| 0.0, &mut due) {
                    fired.fetch_add(due.len(), Ordering::SeqCst);
                    due.clear();
                }
            })
        };
        let scheduler = {
            let svc = Arc::clone(&svc);
            thread::spawn(move || svc.schedule_secs(0.0, 7))
        };

        scheduler.join().unwrap();
        svc.stop();
        timekeeper.join().unwrap();

        let mut left = Vec::new();
        svc.drain(&mut left);
        assert_eq!(
            fired.load(Ordering::SeqCst) + left.len(),
            1,
            "the deadline fires or is drained, exactly once"
        );
    });
}

/// Regression for the PR-8 epoch-fence hardening: `admit` decides and
/// raises the floor in one atomic step, so concurrent admits always leave
/// the floor at the max admitted epoch, the regenerated (higher) epoch is
/// always admitted, and a stale epoch can never pass once the floor rose.
#[test]
fn epoch_floor_admit_and_raise_are_one_atomic_step() {
    loom::model(|| {
        let floor = Arc::new(EpochFloor::new());
        let live = {
            let floor = Arc::clone(&floor);
            thread::spawn(move || floor.admit(2))
        };
        let stale = {
            let floor = Arc::clone(&floor);
            thread::spawn(move || floor.admit(1))
        };
        let live_admitted = live.join().unwrap();
        let _stale_admitted = stale.join().unwrap();

        assert!(live_admitted, "the regenerated epoch always clears the floor");
        assert_eq!(floor.current(), 2, "floor ends at the max admitted epoch");
        assert!(!floor.admit(1), "stale epoch is fenced after the raise");
        assert!(floor.admit(2), "live-epoch retries keep passing");
    });
}
