//! Net-substrate integration tests: real multi-process execution. The
//! coordinator runs in-process (the library side of `--substrate net`) and
//! forks genuine worker processes from the crate's own `repro` binary via
//! the `APIBCD_WORKER_EXE` override (the default `current_exe()` would
//! resolve to the test harness, which has no `worker` subcommand).

use apibcd::algo::AlgoKind;
use apibcd::config::{ExperimentConfig, NetTransport, Preset};
use apibcd::engine::{Experiment, Substrate};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Each test forks child processes and the orphan test counts them, so the
/// cases in this file must not interleave.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn net_cfg() -> ExperimentConfig {
    std::env::set_var("APIBCD_WORKER_EXE", env!("CARGO_BIN_EXE_repro"));
    let mut cfg = ExperimentConfig::preset(Preset::TestLs);
    cfg.agents = 6;
    cfg.walks = 3;
    cfg.topology = "ring".into();
    cfg.tau_api = 0.1;
    cfg.eval_every = 20;
    cfg.net_workers = 2;
    cfg.stop.max_activations = 400;
    cfg
}

/// Live child processes of this process (`/proc/<pid>/stat` ppid field —
/// the field after the parenthesised comm, which may itself contain
/// spaces, so parse from the last `)`).
fn child_process_count() -> usize {
    let me = std::process::id();
    let mut n = 0;
    for entry in std::fs::read_dir("/proc").unwrap() {
        let Ok(entry) = entry else { continue };
        let name = entry.file_name();
        let Some(pid) = name.to_str().and_then(|s| s.parse::<u32>().ok()) else {
            continue;
        };
        let Ok(stat) = std::fs::read_to_string(format!("/proc/{pid}/stat")) else {
            continue;
        };
        let Some((_, rest)) = stat.rsplit_once(')') else { continue };
        let ppid: u32 = rest
            .split_whitespace()
            .nth(1)
            .and_then(|f| f.parse().ok())
            .unwrap_or(0);
        if ppid == me {
            n += 1;
        }
    }
    n
}

#[test]
fn net_substrate_converges_and_counts_wire_bytes() {
    let _g = serial();
    let mut cfg = net_cfg();
    cfg.algos = vec![AlgoKind::ApiBcd];
    let net = Experiment::builder(cfg.clone())
        .substrate(Substrate::Net)
        .run()
        .unwrap();
    let des = Experiment::builder(cfg).substrate(Substrate::Des).run().unwrap();

    assert_eq!(net.traces.len(), 1);
    let t = &net.traces[0];
    assert!(t.name.ends_with("(net)"), "{}", t.name);
    assert!(t.last_metric().is_finite(), "non-finite final metric");
    assert!(
        t.last_metric() < t.points[0].metric,
        "no improvement on the zero model: {} -> {}",
        t.points[0].metric,
        t.last_metric()
    );
    // Satellite claim: the trace carries *real* serialized byte counts,
    // totalled and per worker process.
    assert!(t.bytes_on_wire > 0, "no wire bytes recorded");
    assert_eq!(t.net_worker_bytes.len(), 2, "one entry per worker process");
    assert_eq!(t.net_worker_frames.len(), 2);
    assert!(
        t.net_worker_bytes.iter().all(|&b| b > 0),
        "a worker sent nothing: {:?}",
        t.net_worker_bytes
    );

    // Cross-substrate fidelity: same band the validate harness enforces.
    let gap = (des.traces[0].last_metric() - t.last_metric()).abs();
    assert!(
        gap < 0.25,
        "des {} vs net {} (gap {gap})",
        des.traces[0].last_metric(),
        t.last_metric()
    );
}

#[test]
fn tcp_transport_runs_the_gossip_baseline() {
    let _g = serial();
    let mut cfg = net_cfg();
    cfg.transport = NetTransport::Tcp;
    cfg.algos = vec![AlgoKind::Dgd];
    cfg.stop.max_activations = 200;
    let report = Experiment::builder(cfg)
        .substrate(Substrate::Net)
        .run()
        .unwrap();
    let t = &report.traces[0];
    assert!(t.last_metric().is_finite());
    assert!(
        t.last_metric() < t.points[0].metric,
        "DGD over TCP did not improve: {} -> {}",
        t.points[0].metric,
        t.last_metric()
    );
    assert!(t.bytes_on_wire > 0);
}

#[test]
fn stop_rule_trip_drains_every_worker_process() {
    // The coordinator trips the stop rule mid-flight, broadcasts Stop,
    // collects FinalState and reaps the children — no worker process may
    // outlive the run (the process-level mirror of the thread pool's
    // `pooled_shutdown_under_faults_never_strands_a_worker`).
    let _g = serial();
    let baseline = child_process_count();
    let mut cfg = net_cfg();
    cfg.algos = vec![AlgoKind::ApiBcd];
    cfg.net_workers = 3;
    cfg.stop.max_activations = 150;
    let report = Experiment::builder(cfg)
        .substrate(Substrate::Net)
        .run()
        .unwrap();
    assert!(report.traces[0].last_metric().is_finite());
    assert_eq!(report.traces[0].net_worker_bytes.len(), 3);

    // `run()` reaps synchronously; the poll window only absorbs the OS
    // lagging on zombie cleanup, never a still-running orphan.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let children = child_process_count();
        if children <= baseline {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "orphaned worker process(es): {children} children vs baseline {baseline}"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
}
