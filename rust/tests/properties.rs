//! Property-based tests over the coordinator's invariants (hand-rolled
//! harness in `apibcd::util::proptest`; the proptest crate is not in the
//! offline vendor set).
//!
//! Covered invariants:
//! * topology: connectivity, edge budget, symmetric adjacency, valid
//!   traversal cycles, stochastic Metropolis rows — over random (n, ξ);
//! * routing: every hop of every rule is a graph edge;
//! * DES: event ordering, per-agent service serialization;
//! * token algebra: the I-BCD invariant z = mean(x) under arbitrary update
//!   sequences (eq. 8);
//! * theory: the Theorem 1 descent inequality for exact prox steps on
//!   random convex LS problems;
//! * linalg kernels: the blocked/multi-accumulator `dot`/`axpy`/
//!   `axpy_scale`/`dist2` and `gemv`/`gemv_t`/`ger` agree with scalar f64
//!   references over arbitrary lengths (including sub-lane/sub-block
//!   tails);
//! * serialization: JSON writer/parser round trip on random documents;
//! * timer wheel: revolution-boundary behaviour — slot-0 deadlines,
//!   multi-revolution delays and simultaneous ticks fire exactly once, in
//!   deadline order, never early.

use apibcd::config::RoutingRule;
use apibcd::data::{shard::PartitionKind, Dataset, DatasetProfile, Partition};
use apibcd::graph::Topology;
use apibcd::linalg::{axpy, dist2};
use apibcd::model::{penalty_objective, Task};
use apibcd::sim::{AgentAvailability, EventQueue, TimerWheel, TokenWatch};
use apibcd::solver::{BatchPlanner, GradReq, LocalSolver, NativeSolver, ProxReq};
use apibcd::util::proptest::{run_prop, PropConfig};
use apibcd::util::rng::Rng;

fn cfg(cases: usize, seed: u64) -> PropConfig {
    PropConfig { cases, seed }
}

#[test]
fn prop_random_topology_well_formed() {
    run_prop(
        "random topology well-formed",
        cfg(60, 101),
        |r| {
            let n = 2 + r.below(40);
            let xi = r.next_f64();
            (n, xi, r.next_u64())
        },
        |&(n, xi, seed)| {
            let mut rng = Rng::new(seed);
            let g = Topology::random_connected(n, xi, &mut rng);
            if !g.is_connected() {
                return Err("disconnected".into());
            }
            let max_edges = n * (n - 1) / 2;
            let target = ((xi * max_edges as f64).round() as usize).clamp(n - 1, max_edges);
            if g.num_edges() != target {
                return Err(format!("edges {} != target {target}", g.num_edges()));
            }
            for i in 0..n {
                for j in g.neighbors(i) {
                    if !g.neighbors(j).any(|k| k == i) {
                        return Err(format!("asymmetric edge {i}-{j}"));
                    }
                    if i == j {
                        return Err(format!("self loop at {i}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_traversal_cycle_covers_and_walks_edges() {
    run_prop(
        "traversal cycle valid",
        cfg(40, 202),
        |r| {
            let n = 3 + r.below(30);
            let xi = 0.1 + 0.9 * r.next_f64();
            (n, xi, r.next_u64())
        },
        |&(n, xi, seed)| {
            let mut rng = Rng::new(seed);
            let g = Topology::random_connected(n, xi, &mut rng);
            let cyc = g.traversal_cycle();
            let mut seen = vec![false; n];
            for &u in &cyc {
                seen[u] = true;
            }
            if !seen.iter().all(|&s| s) {
                return Err("cycle misses an agent".into());
            }
            for w in cyc.windows(2) {
                if !g.has_edge(w[0], w[1]) {
                    return Err(format!("hop {:?} not an edge", w));
                }
            }
            if cyc.len() > 1 && !g.has_edge(*cyc.last().unwrap(), cyc[0]) {
                return Err("wrap-around hop not an edge".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_every_topology_kind_well_formed() {
    // Every generator — including the scenario subsystem's scale-free and
    // geometric families — must produce a connected, symmetric, sorted,
    // self-loop-free graph with a canonical edge list and row-stochastic
    // Metropolis rows supported on its edges.
    run_prop(
        "every topology kind well-formed",
        cfg(48, 2024),
        |r| {
            (
                Topology::KINDS[r.below(Topology::KINDS.len())],
                4 + r.below(28),
                0.2 + 0.7 * r.next_f64(),
                r.next_u64(),
            )
        },
        |&(kind, n, xi, seed)| {
            let mut rng = Rng::new(seed);
            let g = Topology::by_kind(kind, n, xi, &mut rng).map_err(|e| e.to_string())?;
            if g.n() != n {
                return Err(format!("{kind}: wrong agent count"));
            }
            if !g.is_connected() {
                return Err(format!("{kind}: disconnected"));
            }
            let mut degree_sum = 0usize;
            for i in 0..n {
                let d = g.degree(i);
                if d == 0 || d > n - 1 {
                    return Err(format!("{kind}: degree {d} out of [1, {}] at {i}", n - 1));
                }
                degree_sum += d;
                let mut prev = None;
                for j in g.neighbors(i) {
                    if j == i {
                        return Err(format!("{kind}: self loop at {i}"));
                    }
                    if !g.neighbors(j).any(|k| k == i) {
                        return Err(format!("{kind}: asymmetric edge {i}-{j}"));
                    }
                    if let Some(p) = prev {
                        if p >= j {
                            return Err(format!("{kind}: adjacency of {i} not sorted/deduped"));
                        }
                    }
                    prev = Some(j);
                }
            }
            if degree_sum != 2 * g.num_edges() {
                return Err(format!(
                    "{kind}: degree sum {degree_sum} != 2·|E| = {}",
                    2 * g.num_edges()
                ));
            }
            let es = g.edges();
            for w in es.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("{kind}: edge list not strictly sorted"));
                }
            }
            for &(a, b) in &es {
                if a >= b || !g.has_edge(a, b) {
                    return Err(format!("{kind}: non-canonical edge ({a},{b})"));
                }
            }
            for i in 0..n {
                let row = g.metropolis_row(i);
                let sum: f64 = row.iter().map(|&(_, p)| p).sum();
                if (sum - 1.0).abs() > 1e-9 {
                    return Err(format!("{kind}: metropolis row {i} sums to {sum}"));
                }
                for &(j, p) in &row {
                    if p < -1e-12 {
                        return Err(format!("{kind}: negative probability {p} at row {i}"));
                    }
                    if j != i && !g.has_edge(i, j) {
                        return Err(format!("{kind}: metropolis mass on non-edge {i}-{j}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_implicit_topology_agrees_with_materialized() {
    // The implicit representations (ring/grid/torus/star/complete computed
    // arithmetically, scale-free/geometric re-derived from a seeded hash)
    // must answer every query identically to their fully materialized
    // adjacency-list forms — neighbors, degrees, edge membership, edge
    // lists, and connectivity.
    run_prop(
        "implicit topology ≡ materialized",
        cfg(64, 0x5EED_0902),
        |r| {
            (
                Topology::KINDS[r.below(Topology::KINDS.len())],
                2 + r.below(40),
                0.2 + 0.7 * r.next_f64(),
                r.next_u64(),
            )
        },
        |&(kind, n, xi, seed)| {
            let mut rng = Rng::new(seed);
            let g = Topology::by_kind(kind, n, xi, &mut rng).map_err(|e| e.to_string())?;
            let m = g.materialize();
            if m.n() != g.n() {
                return Err(format!("{kind}: materialized n {} != {}", m.n(), g.n()));
            }
            for i in 0..n {
                let gi: Vec<usize> = g.neighbors(i).collect();
                let mi: Vec<usize> = m.neighbors(i).collect();
                if gi != mi {
                    return Err(format!("{kind}: neighbors({i}) {gi:?} != {mi:?}"));
                }
                if g.degree(i) != m.degree(i) {
                    return Err(format!(
                        "{kind}: degree({i}) {} != {}",
                        g.degree(i),
                        m.degree(i)
                    ));
                }
                for j in 0..n {
                    if g.has_edge(i, j) != m.has_edge(i, j) {
                        return Err(format!("{kind}: has_edge({i},{j}) disagrees"));
                    }
                }
            }
            if g.num_edges() != m.num_edges() {
                return Err(format!(
                    "{kind}: num_edges {} != {}",
                    g.num_edges(),
                    m.num_edges()
                ));
            }
            if g.edges() != m.edges() {
                return Err(format!("{kind}: edge lists differ"));
            }
            if g.is_connected() != m.is_connected() {
                return Err(format!("{kind}: connectivity disagrees"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_metropolis_rows_stochastic_and_supported() {
    run_prop(
        "metropolis rows",
        cfg(40, 303),
        |r| (2 + r.below(25), r.next_u64()),
        |&(n, seed)| {
            let mut rng = Rng::new(seed);
            let g = Topology::random_connected(n, 0.5, &mut rng);
            for i in 0..n {
                let row = g.metropolis_row(i);
                let sum: f64 = row.iter().map(|&(_, p)| p).sum();
                if (sum - 1.0).abs() > 1e-9 {
                    return Err(format!("row {i} sums to {sum}"));
                }
                for &(j, p) in &row {
                    if p < -1e-12 {
                        return Err(format!("negative probability {p}"));
                    }
                    if j != i && !g.has_edge(i, j) {
                        return Err(format!("mass on non-edge {i}-{j}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_routing_hops_are_edges() {
    run_prop(
        "routing hops are edges",
        cfg(30, 404),
        |r| {
            let n = 3 + r.below(20);
            let rule = match r.below(3) {
                0 => RoutingRule::Cycle,
                1 => RoutingRule::Uniform,
                _ => RoutingRule::Metropolis,
            };
            (n, rule, r.next_u64())
        },
        |&(n, rule, seed)| {
            use apibcd::engine::Router;
            let mut rng = Rng::new(seed);
            let g = Topology::random_connected(n, 0.4, &mut rng);
            let mut router = Router::new(rule, &g, 2);
            for m in 0..2 {
                let mut at = router.start(m, &g, &mut rng);
                for _ in 0..3 * n {
                    let next = router.next(m, at, &g, &mut rng);
                    if !g.has_edge(at, next) {
                        return Err(format!("{rule:?} walk {m}: {at}->{next} not an edge"));
                    }
                    at = next;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_event_queue_pops_in_time_order() {
    run_prop(
        "event queue ordering",
        cfg(50, 505),
        |r| {
            let n = 1 + r.below(200);
            (0..n)
                .map(|_| (r.next_f64() * 100.0, r.below(8), r.below(16)))
                .collect::<Vec<_>>()
        },
        |events| {
            let mut q = EventQueue::new();
            for &(t, tok, ag) in events {
                q.push(t, tok, ag);
            }
            let mut last = f64::NEG_INFINITY;
            let mut count = 0;
            while let Some(e) = q.pop() {
                if e.time < last {
                    return Err(format!("time went backwards: {} < {last}", e.time));
                }
                last = e.time;
                count += 1;
            }
            if count != events.len() {
                return Err("lost events".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_agent_availability_serializes() {
    run_prop(
        "agent service serialization",
        cfg(50, 606),
        |r| {
            let n_agents = 1 + r.below(5);
            let jobs: Vec<(usize, f64, f64)> = (0..(1 + r.below(50)))
                .map(|_| (r.below(n_agents), r.next_f64(), r.next_f64() * 0.1))
                .collect();
            (n_agents, jobs)
        },
        |(n_agents, jobs)| {
            let mut av = AgentAvailability::new(*n_agents);
            let mut sorted = jobs.clone();
            sorted.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let mut last_end = vec![0.0f64; *n_agents];
            for &(agent, arrival, dur) in &sorted {
                let (start, end) = av.serve(agent, arrival, dur);
                if start + 1e-15 < arrival {
                    return Err("service before arrival".into());
                }
                if start + 1e-15 < last_end[agent] {
                    return Err("overlapping service at one agent".into());
                }
                if (end - start - dur).abs() > 1e-12 {
                    return Err("wrong service duration".into());
                }
                last_end[agent] = end;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ibcd_token_tracks_block_mean() {
    // eq. (8): if z⁰ = mean(x⁰), then z = mean(x) after any update sequence.
    run_prop(
        "I-BCD token algebra",
        cfg(50, 707),
        |r| {
            let n = 2 + r.below(10);
            let dim = 1 + r.below(8);
            let steps: Vec<(usize, Vec<f32>)> = (0..(1 + r.below(60)))
                .map(|_| {
                    (
                        r.below(n),
                        (0..dim).map(|_| r.normal_f32()).collect::<Vec<f32>>(),
                    )
                })
                .collect();
            (n, dim, steps)
        },
        |(n, dim, steps)| {
            let mut xs = vec![vec![0.0f32; *dim]; *n];
            let mut z = vec![0.0f32; *dim];
            for (agent, x_new) in steps {
                for j in 0..*dim {
                    z[j] += (x_new[j] - xs[*agent][j]) / *n as f32;
                }
                xs[*agent] = x_new.clone();
            }
            let mut mean = vec![0.0f32; *dim];
            for x in &xs {
                axpy(1.0 / *n as f32, x, &mut mean);
            }
            if dist2(&z, &mean) > 1e-6 {
                return Err(format!("drift ‖z − mean(x)‖² = {}", dist2(&z, &mean)));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_theorem1_descent_holds() {
    // Exact prox step at a random state descends F by at least the Theorem 1
    // quantity (up to f32 slack).
    let ds = Dataset::load(
        DatasetProfile::by_name("test_ls").unwrap(),
        "/nonexistent",
        9,
    )
    .unwrap();
    let part = Partition::new(&ds, 2, PartitionKind::Iid).unwrap();
    let dim = ds.profile.features;

    run_prop(
        "Theorem 1 descent",
        cfg(40, 808),
        |r| {
            let agent = r.below(2);
            let xs: Vec<Vec<f32>> = (0..2)
                .map(|_| (0..dim).map(|_| r.normal_f32()).collect())
                .collect();
            // Theorem 1 holds along the algorithm's trajectory, where the
            // token invariant z = mean(x) is maintained (the proof's step
            // (b) uses z^{k+1} = (1/N)Σ x_i^{k+1}) — generate states on
            // that manifold.
            let mut z = vec![0.0f32; dim];
            for x in &xs {
                axpy(0.5, x, &mut z);
            }
            let tau = 0.2 + r.next_f64() as f32 * 2.0;
            (agent, xs, z, tau)
        },
        |(agent, xs, z, tau)| {
            let mut solver = NativeSolver::new(Task::Regression, dim + 3); // exact CG
            let tzsum: Vec<f32> = z.iter().map(|v| tau * v).collect();
            let out = solver
                .prox(&part.shards[*agent], &xs[*agent], &tzsum, *tau)
                .map_err(|e| e.to_string())?;

            // z update (eq. 8), N = 2.
            let mut z_new = z.clone();
            for j in 0..dim {
                z_new[j] += (out.w[j] - xs[*agent][j]) / 2.0;
            }
            let mut xs_new = xs.clone();
            xs_new[*agent] = out.w.clone();

            let f_old = penalty_objective(
                Task::Regression,
                &part.shards,
                xs,
                std::slice::from_ref(z),
                *tau as f64,
            );
            let f_new = penalty_objective(
                Task::Regression,
                &part.shards,
                &xs_new,
                std::slice::from_ref(&z_new),
                *tau as f64,
            );
            let bound = -(*tau as f64) / 2.0 * dist2(&out.w, &xs[*agent]) as f64
                - (*tau as f64) * 2.0 / 2.0 * dist2(&z_new, z) as f64;
            // f_new − f_old ≤ bound up to f32 slack: the CG solve and the
            // objective evaluation are f32, so allow a relative tolerance.
            let slack = 1e-3 + 1e-2 * bound.abs();
            if f_new - f_old > bound + slack {
                return Err(format!(
                    "descent violated: Δ={} bound={bound}",
                    f_new - f_old
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blocked_kernels_match_scalar_reference() {
    // The chunked multi-accumulator kernels must agree with a plain f64
    // scalar reference to 1e-5 relative tolerance, for every length
    // including the sub-lane (<8) and sub-block (<128) tails.
    use apibcd::linalg::{axpy_scale, dot};
    run_prop(
        "blocked kernels ≈ scalar reference",
        cfg(80, 1616),
        |r| {
            let n = r.below(300); // covers 0, <lane, <block, >block
            let a: Vec<f32> = (0..n).map(|_| r.normal_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| r.normal_f32()).collect();
            let alpha = r.normal_f32();
            let beta = r.normal_f32();
            (a, b, alpha, beta)
        },
        |(a, b, alpha, beta)| {
            // dot: |got − Σ aᵢbᵢ| ≤ 1e-5·(1 + Σ|aᵢbᵢ|)
            let want: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let mag: f64 = a
                .iter()
                .zip(b)
                .map(|(&x, &y)| (x as f64 * y as f64).abs())
                .sum();
            let got = dot(a, b) as f64;
            if (got - want).abs() > 1e-5 * (1.0 + mag) {
                return Err(format!("dot {got} vs {want} (n={})", a.len()));
            }
            // dist2: magnitude equals the (all-positive) reference.
            let want: f64 = a
                .iter()
                .zip(b)
                .map(|(&x, &y)| {
                    let d = x as f64 - y as f64;
                    d * d
                })
                .sum();
            let got = dist2(a, b) as f64;
            if (got - want).abs() > 1e-5 * (1.0 + want) {
                return Err(format!("dist2 {got} vs {want}"));
            }
            // axpy, element-wise.
            let mut y = b.clone();
            axpy(*alpha, a, &mut y);
            for i in 0..a.len() {
                let want = b[i] as f64 + *alpha as f64 * a[i] as f64;
                if (y[i] as f64 - want).abs() > 1e-5 * (1.0 + want.abs()) {
                    return Err(format!("axpy[{i}] {} vs {want}", y[i]));
                }
            }
            // fused axpy_scale, element-wise.
            let mut y = b.clone();
            axpy_scale(*alpha, a, *beta, &mut y);
            for i in 0..a.len() {
                let want = *alpha as f64 * a[i] as f64 + *beta as f64 * b[i] as f64;
                if (y[i] as f64 - want).abs() > 1e-5 * (1.0 + want.abs()) {
                    return Err(format!("axpy_scale[{i}] {} vs {want}", y[i]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gemv_family_matches_scalar_reference() {
    // gemv / gemv_t / ger over random shapes (including 0 rows and
    // col counts below the lane/block widths) vs naive f64 loops.
    use apibcd::linalg::{gemv, gemv_t, ger};
    run_prop(
        "gemv family ≈ scalar reference",
        cfg(60, 1717),
        |r| {
            let rows = r.below(20);
            let cols = 1 + r.below(150);
            let a: Vec<f32> = (0..rows * cols).map(|_| r.normal_f32()).collect();
            let x: Vec<f32> = (0..cols).map(|_| r.normal_f32()).collect();
            let xt: Vec<f32> = (0..rows).map(|_| r.normal_f32()).collect();
            (rows, cols, a, x, xt)
        },
        |(rows, cols, a, x, xt)| {
            let (rows, cols) = (*rows, *cols);
            let tol = |mag: f64| 1e-5 * (1.0 + mag);
            // y = A x
            let mut y = vec![0.0f32; rows];
            gemv(a, rows, cols, x, &mut y);
            for i in 0..rows {
                let mut want = 0.0f64;
                let mut mag = 0.0f64;
                for j in 0..cols {
                    let t = a[i * cols + j] as f64 * x[j] as f64;
                    want += t;
                    mag += t.abs();
                }
                if (y[i] as f64 - want).abs() > tol(mag) {
                    return Err(format!("gemv[{i}] {} vs {want}", y[i]));
                }
            }
            // y = Aᵀ x
            let mut yt = vec![0.0f32; cols];
            gemv_t(a, rows, cols, xt, &mut yt);
            for j in 0..cols {
                let mut want = 0.0f64;
                let mut mag = 0.0f64;
                for i in 0..rows {
                    let t = a[i * cols + j] as f64 * xt[i] as f64;
                    want += t;
                    mag += t.abs();
                }
                if (yt[j] as f64 - want).abs() > tol(mag) {
                    return Err(format!("gemv_t[{j}] {} vs {want}", yt[j]));
                }
            }
            // A += xt ⊗ x (rank-1)
            let mut g = a.clone();
            ger(xt, x, &mut g);
            for i in 0..rows {
                for j in 0..cols {
                    let want = a[i * cols + j] as f64 + xt[i] as f64 * x[j] as f64;
                    let got = g[i * cols + j] as f64;
                    if (got - want).abs() > tol(want.abs()) {
                        return Err(format!("ger[{i},{j}] {got} vs {want}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    use apibcd::util::json::{to_string, Json};
    fn gen_json(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.below(2) == 0),
            2 => Json::Num((r.below(2_000_000) as f64 - 1_000_000.0) / 64.0),
            3 => Json::Str(format!("s{}τ", r.below(1000))),
            4 => Json::Arr((0..r.below(5)).map(|_| gen_json(r, depth - 1)).collect()),
            _ => Json::Obj(
                (0..r.below(5))
                    .map(|i| (format!("k{i}"), gen_json(r, depth - 1)))
                    .collect(),
            ),
        }
    }
    run_prop(
        "json round trip",
        cfg(80, 909),
        |r| gen_json(r, 3),
        |doc| {
            let text = to_string(doc);
            let parsed = Json::parse(&text).map_err(|e| e.to_string())?;
            if &parsed != doc {
                return Err(format!("round trip mismatch: {text}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partition_conserves_rows() {
    run_prop(
        "partition row conservation",
        cfg(20, 111),
        |r| (1 + r.below(4), r.next_u64()),
        |&(n_agents, seed)| {
            let ds = Dataset::load(
                DatasetProfile::by_name("test_ls").unwrap(),
                "/nonexistent",
                seed,
            )
            .map_err(|e| e.to_string())?;
            let part =
                Partition::new(&ds, n_agents, PartitionKind::Iid).map_err(|e| e.to_string())?;
            if part.total_active() != ds.n_train() {
                return Err(format!(
                    "active {} != train {}",
                    part.total_active(),
                    ds.n_train()
                ));
            }
            for s in &part.shards {
                let mask_sum: f32 = s.mask.iter().sum();
                if mask_sum as usize != s.active {
                    return Err("mask/active mismatch".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_theorem2_descent_holds() {
    // API-BCD with fresh token sharing (Theorem 2): at states where all
    // local copies equal the live tokens AND z_m = mean(x) ∀m (the
    // trajectory manifold), one exact block update descends F(x, z) by at
    // least (τM/2)‖Δx‖² + (τN/2)Σ_m‖Δz_m‖².
    let ds = Dataset::load(
        DatasetProfile::by_name("test_ls").unwrap(),
        "/nonexistent",
        13,
    )
    .unwrap();
    let part = Partition::new(&ds, 2, PartitionKind::Iid).unwrap();
    let dim = ds.profile.features;

    run_prop(
        "Theorem 2 descent",
        cfg(40, 1212),
        |r| {
            let agent = r.below(2);
            let m_walks = 1 + r.below(4);
            let xs: Vec<Vec<f32>> = (0..2)
                .map(|_| (0..dim).map(|_| r.normal_f32()).collect())
                .collect();
            let mut zbar = vec![0.0f32; dim];
            for x in &xs {
                axpy(0.5, x, &mut zbar);
            }
            let tau = 0.2 + r.next_f64() as f32 * 1.5;
            (agent, m_walks, xs, zbar, tau)
        },
        |(agent, m_walks, xs, zbar, tau)| {
            let m = *m_walks;
            let n = 2usize;
            // Fresh sharing: every token (and copy) equals z̄ = mean(x).
            let zs: Vec<Vec<f32>> = (0..m).map(|_| zbar.clone()).collect();
            let mut solver = NativeSolver::new(Task::Regression, dim + 3);
            let mut tzsum = vec![0.0f32; dim];
            for z in &zs {
                axpy(*tau, z, &mut tzsum);
            }
            let tau_m = *tau * m as f32;
            let out = solver
                .prox(&part.shards[*agent], &xs[*agent], &tzsum, tau_m)
                .map_err(|e| e.to_string())?;

            // Every token takes the (12b) increment in the fresh-sharing
            // regime (all copies are synchronized).
            let mut zs_new = zs.clone();
            for z in zs_new.iter_mut() {
                for j in 0..dim {
                    z[j] += (out.w[j] - xs[*agent][j]) / n as f32;
                }
            }
            let mut xs_new = xs.clone();
            xs_new[*agent] = out.w.clone();

            let f_old =
                penalty_objective(Task::Regression, &part.shards, xs, &zs, *tau as f64);
            let f_new =
                penalty_objective(Task::Regression, &part.shards, &xs_new, &zs_new, *tau as f64);
            let dz: f64 = zs_new
                .iter()
                .zip(&zs)
                .map(|(a, b)| dist2(a, b) as f64)
                .sum();
            let bound = -(*tau as f64) * m as f64 / 2.0 * dist2(&out.w, &xs[*agent]) as f64
                - (*tau as f64) * n as f64 / 2.0 * dz;
            let slack = 1e-3 + 1e-2 * bound.abs();
            if f_new - f_old > bound + slack {
                return Err(format!(
                    "Theorem 2 violated (M={m}): Δ={} bound={bound}",
                    f_new - f_old
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_theorem3_descent_holds() {
    // gAPI-BCD (eq. 15) under fresh sharing: descent with the weaker
    // Theorem 3 constant (τM/2 + ρ − L/2), given ρ ≥ L.
    let ds = Dataset::load(
        DatasetProfile::by_name("test_ls").unwrap(),
        "/nonexistent",
        21,
    )
    .unwrap();
    let part = Partition::new(&ds, 2, PartitionKind::Iid).unwrap();
    let dim = ds.profile.features;

    run_prop(
        "Theorem 3 descent",
        cfg(40, 1313),
        |r| {
            let agent = r.below(2);
            let m_walks = 1 + r.below(3);
            let xs: Vec<Vec<f32>> = (0..2)
                .map(|_| (0..dim).map(|_| 0.5 * r.normal_f32()).collect())
                .collect();
            let mut zbar = vec![0.0f32; dim];
            for x in &xs {
                axpy(0.5, x, &mut zbar);
            }
            let tau = 0.2 + r.next_f64() as f32;
            (agent, m_walks, xs, zbar, tau)
        },
        |(agent, m_walks, xs, zbar, tau)| {
            let m = *m_walks;
            let n = 2usize;
            let shard = &part.shards[*agent];
            let d = shard.active.max(1) as f32;
            let lhat = shard.frob_sq() / d; // L upper bound for LS
            let rho = lhat; // ρ ≥ L ⇒ Theorem 3 constant positive
            let zs: Vec<Vec<f32>> = (0..m).map(|_| zbar.clone()).collect();

            let mut solver = NativeSolver::new(Task::Regression, 5);
            let g = solver
                .grad(shard, &xs[*agent])
                .map_err(|e| e.to_string())?;
            let tau_m = *tau * m as f32;
            let denom = rho + tau_m;
            let mut x_new = vec![0.0f32; dim];
            let mut tzsum = vec![0.0f32; dim];
            for z in &zs {
                axpy(*tau, z, &mut tzsum);
            }
            for j in 0..dim {
                x_new[j] = (rho * xs[*agent][j] + tzsum[j] - g.w[j]) / denom;
            }

            let mut zs_new = zs.clone();
            for z in zs_new.iter_mut() {
                for j in 0..dim {
                    z[j] += (x_new[j] - xs[*agent][j]) / n as f32;
                }
            }
            let mut xs_new = xs.clone();
            xs_new[*agent] = x_new.clone();

            let f_old =
                penalty_objective(Task::Regression, &part.shards, xs, &zs, *tau as f64);
            let f_new =
                penalty_objective(Task::Regression, &part.shards, &xs_new, &zs_new, *tau as f64);
            let dz: f64 = zs_new
                .iter()
                .zip(&zs)
                .map(|(a, b)| dist2(a, b) as f64)
                .sum();
            let coeff = (*tau as f64) * m as f64 / 2.0 + rho as f64 - lhat as f64 / 2.0;
            let bound = -coeff * dist2(&x_new, &xs[*agent]) as f64
                - (*tau as f64) * n as f64 / 2.0 * dz;
            let slack = 1e-3 + 1e-2 * (f_old.abs() + bound.abs());
            if f_new - f_old > bound + slack {
                return Err(format!(
                    "Theorem 3 violated (M={m}): Δ={} bound={bound}",
                    f_new - f_old
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fault_transmit_expected_attempts() {
    use apibcd::sim::FaultModel;
    run_prop(
        "geometric retransmission count",
        cfg(20, 1414),
        |r| (r.next_f64() * 0.6, r.next_u64()),
        |&(p, seed)| {
            let model = FaultModel::lossy(p);
            let mut rng = Rng::new(seed);
            let n = 4000;
            let mut total = 0u64;
            for _ in 0..n {
                let (a, _) = model.transmit(&mut rng);
                total += a;
            }
            let mean = total as f64 / n as f64;
            let expect = 1.0 / (1.0 - p); // geometric mean attempts
            if (mean - expect).abs() > 0.15 * expect + 0.05 {
                return Err(format!("p={p}: mean {mean} vs expected {expect}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_objective_tracker_matches_naive() {
    // The incremental tracker (reading blocks straight out of the arena)
    // must agree with the direct O(N·s·p) evaluation after arbitrary
    // update sequences.
    let ds = Dataset::load(
        DatasetProfile::by_name("test_ls").unwrap(),
        "/nonexistent",
        31,
    )
    .unwrap();
    let part = Partition::new(&ds, 4, PartitionKind::Iid).unwrap();
    let dim = ds.profile.features;

    run_prop(
        "objective tracker vs naive",
        cfg(30, 1515),
        |r| {
            let steps: Vec<(usize, Vec<f32>)> = (0..(1 + r.below(40)))
                .map(|_| (r.below(4), (0..dim).map(|_| r.normal_f32()).collect()))
                .collect();
            let m = 1 + r.below(3);
            let zs: Vec<Vec<f32>> = (0..m)
                .map(|_| (0..dim).map(|_| r.normal_f32()).collect())
                .collect();
            let tau = 0.1 + r.next_f64();
            (steps, zs, tau)
        },
        |(steps, zs, tau)| {
            use apibcd::model::{BlockStore, ObjectiveTracker};
            let mut blocks = BlockStore::new(4, dim);
            let mut tracker = ObjectiveTracker::new(Task::Regression, 4, dim);
            for (agent, x_new) in steps {
                tracker.block_updated(*agent, blocks.row(*agent), x_new);
                blocks.row_mut(*agent).copy_from_slice(x_new);
            }
            let fast = tracker.objective(
                &part.shards,
                &blocks,
                zs.iter().map(|z| z.as_slice()),
                *tau,
            );
            let xs: Vec<Vec<f32>> = (0..4).map(|i| blocks.row(i).to_vec()).collect();
            let naive = penalty_objective(Task::Regression, &part.shards, &xs, zs, *tau);
            let tol = 1e-6 + 1e-9 * naive.abs() + 1e-4;
            if (fast - naive).abs() > tol {
                return Err(format!("tracker {fast} vs naive {naive}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_running_block_sum_matches_from_scratch_recompute() {
    // The O(dim) record path stands on the running block-sum maintained in
    // `block_updated`. After arbitrary interleavings of block updates the
    // incremental f64 sums must agree with a from-scratch recompute over
    // the arena to f64 rounding (a few parts in 1e14), and the *recorded*
    // f32 consensus mean — the value that lands in the trace — must be
    // bit-identical to the from-scratch mean, since f64 accumulation drift
    // sits ten orders of magnitude below one f32 ulp.
    run_prop(
        "running block-sum vs from-scratch",
        cfg(48, 1616),
        |r| {
            let n = 2 + r.below(6);
            let dim = 1 + r.below(9);
            let steps: Vec<(usize, Vec<f32>)> = (0..(1 + r.below(60)))
                .map(|_| (r.below(n), (0..dim).map(|_| r.normal_f32()).collect()))
                .collect();
            (n, dim, steps)
        },
        |(n, dim, steps)| {
            use apibcd::model::{BlockStore, ObjectiveTracker};
            let (n, dim) = (*n, *dim);
            let mut blocks = BlockStore::new(n, dim);
            let mut tracker = ObjectiveTracker::new(Task::Regression, n, dim);
            for (agent, x_new) in steps {
                tracker.block_updated(*agent, blocks.row(*agent), x_new);
                blocks.row_mut(*agent).copy_from_slice(x_new);
            }
            // From-scratch f64 recompute over the arena rows.
            let mut fresh = vec![0.0f64; dim];
            for i in 0..n {
                for (s, &v) in fresh.iter_mut().zip(blocks.row(i)) {
                    *s += v as f64;
                }
            }
            for (j, (&inc, &scr)) in tracker.block_sum().iter().zip(&fresh).enumerate() {
                let tol = 1e-12 * (1.0 + scr.abs());
                if (inc - scr).abs() > tol {
                    return Err(format!("sum_x[{j}]: incremental {inc} vs fresh {scr}"));
                }
            }
            // The recorded f32 mean is bit-identical to from-scratch.
            let mut inc_mean = vec![0.0f32; dim];
            tracker.mean_into(&mut inc_mean);
            for j in 0..dim {
                let scratch = (fresh[j] / n as f64) as f32;
                if inc_mean[j].to_bits() != scratch.to_bits() {
                    return Err(format!(
                        "mean[{j}]: incremental {:?} vs from-scratch {:?}",
                        inc_mean[j], scratch
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_epoch_fencing_admits_exactly_one_live_token_per_walk() {
    // The tentpole safety property: under ANY interleaving of permanent
    // loss, lease-expiry regeneration and stale (resurfaced) deliveries,
    // the watchdog admits exactly one live token per walk — a lost token
    // that floats back can never commit an activation, and the live
    // (latest-epoch) token is never fenced. Lost tokens are modelled as
    // "ghosts" that stay deliverable forever, which is strictly harsher
    // than either substrate (the DES can't even resurface one).
    run_prop(
        "epoch fencing: one live token per walk",
        cfg(80, 909),
        |r| {
            let walks = 1 + r.below(4);
            let steps = 20 + r.below(120);
            (walks, steps, r.next_u64())
        },
        |&(walks, steps, seed)| {
            let mut rng = Rng::new(seed);
            let mut watch = TokenWatch::new(walks);
            let mut live: Vec<u32> = vec![0; walks];
            let mut ghosts: Vec<Vec<u32>> = vec![Vec::new(); walks];
            let (mut losses, mut stale_attempts) = (0u64, 0u64);
            let mut k = 0u64;
            for _ in 0..steps {
                let m = rng.below(walks);
                match rng.below(3) {
                    0 => {
                        // Permanent loss: the live token becomes a ghost,
                        // the watchdog regenerates under a bumped epoch.
                        ghosts[m].push(live[m]);
                        watch.lost(m, k);
                        live[m] = watch.regenerate(m);
                        losses += 1;
                    }
                    1 => {
                        // The live token arrives and is serviced.
                        if !watch.admit(m, live[m]) {
                            return Err(format!(
                                "live epoch {} fenced on walk {m}",
                                live[m]
                            ));
                        }
                        k += 1;
                        watch.serviced(m, k);
                    }
                    _ => {
                        // A random stale token resurfaces: must be a no-op.
                        if !ghosts[m].is_empty() {
                            let g = ghosts[m][rng.below(ghosts[m].len())];
                            stale_attempts += 1;
                            if watch.admit(m, g) {
                                return Err(format!(
                                    "stale epoch {g} admitted on walk {m} (live {})",
                                    live[m]
                                ));
                            }
                        }
                    }
                }
            }
            // After the interleaving: per walk, the live epoch (and only
            // it) still commits, and the accounting matches the history.
            for m in 0..walks {
                if !watch.admit(m, live[m]) {
                    return Err(format!("final live epoch fenced on walk {m}"));
                }
                for g in &ghosts[m] {
                    stale_attempts += 1;
                    if watch.admit(m, *g) {
                        return Err(format!("ghost epoch {g} admitted on walk {m}"));
                    }
                }
            }
            if watch.tokens_regenerated != losses {
                return Err(format!(
                    "regenerations {} != losses {losses}",
                    watch.tokens_regenerated
                ));
            }
            if watch.stale_drops != stale_attempts {
                return Err(format!(
                    "stale_drops {} != fenced deliveries {stale_attempts}",
                    watch.stale_drops
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_timer_wheel_revolution_boundaries() {
    // PR-8 satellite: the wheel's ring arithmetic at its seams. Deadlines
    // are biased onto slot 0 (exact multiples of nslots), pile several
    // onto the *same* tick, and reach many revolutions out; the cursor is
    // then advanced tick-by-tick so "fires exactly once, in deadline
    // order, never early" is checked at every single boundary — including
    // each wrap through slot 0.
    run_prop(
        "timer wheel revolution boundaries",
        cfg(80, 1010),
        |r| {
            let nslots = 1 + r.below(6);
            let revolutions = 2 + r.below(4);
            let n = 1 + r.below(24);
            let deadlines: Vec<u64> = (0..n)
                .map(|_| {
                    let max_tick = (nslots * revolutions) as u64;
                    if r.below(3) == 0 {
                        // Exact slot-0 hit, k whole revolutions out.
                        (r.below(revolutions + 1) * nslots) as u64
                    } else {
                        r.below(max_tick as usize + 1) as u64
                    }
                })
                .collect();
            (nslots, deadlines)
        },
        |&(nslots, ref deadlines)| {
            let mut wheel: TimerWheel<usize> = TimerWheel::new(1.0, nslots);
            for (id, &t) in deadlines.iter().enumerate() {
                wheel.schedule_at(t, id);
            }
            let mut fired_at: Vec<Option<u64>> = vec![None; deadlines.len()];
            let last = deadlines.iter().copied().max().unwrap_or(0);
            let mut out = Vec::new();
            for now in 0..=last {
                out.clear();
                wheel.advance_to(now, &mut out);
                for &id in &out {
                    if let Some(prev) = fired_at[id] {
                        return Err(format!("id {id} fired twice (ticks {prev} and {now})"));
                    }
                    if now < deadlines[id] {
                        return Err(format!(
                            "id {id} fired early: tick {now} < deadline {}",
                            deadlines[id]
                        ));
                    }
                    if now > deadlines[id] {
                        return Err(format!(
                            "id {id} fired late under tick-by-tick advance: \
                             tick {now} > deadline {}",
                            deadlines[id]
                        ));
                    }
                    fired_at[id] = Some(now);
                }
            }
            // Advancing one tick at a time means firing order IS deadline
            // order; every scheduled entry must have fired by `last`.
            if let Some(id) = fired_at.iter().position(Option::is_none) {
                return Err(format!(
                    "id {id} (deadline {}) never fired by tick {last}",
                    deadlines[id]
                ));
            }
            if !wheel.is_empty() {
                return Err(format!("{} entries left on the wheel", wheel.len()));
            }
            // A deadline already at the cursor's past clamps forward and
            // fires on the very next advance — the slot-0 stale case.
            wheel.schedule_at(0, usize::MAX);
            out.clear();
            wheel.advance_to(last + 1, &mut out);
            if out != vec![usize::MAX] {
                return Err(format!("stale deadline did not clamp-fire: {out:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batched_solves_bit_identical_to_sequential() {
    // Random compositions through the BatchPlanner — mixed shards, mixed
    // prox/grad, batch caps 1..=8, partial flushes at arbitrary points —
    // must reproduce the one-at-a-time `prox_into`/`grad_into` outputs
    // bit-for-bit (the LocalSolver batch contract).
    use std::cell::RefCell;

    run_prop(
        "batched solves bit-identical",
        cfg(18, 1313),
        |r| {
            let profile = r.below(3);
            let n_agents = 2 + r.below(3);
            let cap = 1 + r.below(8);
            (profile, n_agents, cap, r.next_u64())
        },
        |&(profile, n_agents, cap, seed)| {
            let name = ["test_ls", "test_logit", "test_smax"][profile];
            let prof = DatasetProfile::by_name(name).unwrap();
            let ds = Dataset::load(prof, "/nonexistent", 1).map_err(|e| e.to_string())?;
            let shards = Partition::new(&ds, n_agents, PartitionKind::Iid)
                .map_err(|e| e.to_string())?
                .shards;
            let dim = shards[0].features * shards[0].classes;
            let mut rng = Rng::new(seed);
            let n_reqs = 1 + rng.below(12);

            let mut planner: BatchPlanner<usize> = BatchPlanner::new(cap);
            let mut batched = NativeSolver::new(prof.task, 5);
            let mut seq = NativeSolver::new(prof.task, 5);
            let outs: RefCell<Vec<Option<Vec<f32>>>> = RefCell::new(vec![None; n_reqs]);
            let errs: RefCell<Vec<String>> = RefCell::new(Vec::new());
            let mut wants: Vec<Vec<f32>> = Vec::new();
            for i in 0..n_reqs {
                let agent = rng.below(n_agents);
                let vec_of = |rng: &mut Rng, scale: f32| -> Vec<f32> {
                    (0..dim).map(|_| scale * rng.normal_f32()).collect()
                };
                if rng.below(3) > 0 {
                    let w0 = vec_of(&mut rng, 0.3);
                    let tzsum = vec_of(&mut rng, 0.2);
                    let tau_m = 0.25 + 0.75 * rng.next_f64() as f32;
                    let mut want = Vec::new();
                    seq.prox_into(&shards[agent], &w0, &tzsum, tau_m, &mut want)
                        .map_err(|e| e.to_string())?;
                    wants.push(want);
                    planner.push_prox(
                        ProxReq { agent, w0, tzsum, tau_m, out: Vec::new(), wall_secs: 0.0 },
                        i,
                    );
                } else {
                    let w = vec_of(&mut rng, 0.3);
                    let mut want = Vec::new();
                    seq.grad_into(&shards[agent], &w, &mut want)
                        .map_err(|e| e.to_string())?;
                    wants.push(want);
                    planner.push_grad(GradReq { agent, w, out: Vec::new(), wall_secs: 0.0 }, i);
                }
                // Partial flush: whenever the cap fills, and at random
                // points in between (idle-queue early flush).
                if planner.full() || rng.below(4) == 0 {
                    planner.flush(
                        &mut batched,
                        &shards,
                        |res, tag| match res {
                            Ok(r) => outs.borrow_mut()[tag] = Some(r.out),
                            Err(e) => errs.borrow_mut().push(e.to_string()),
                        },
                        |res, tag| match res {
                            Ok(r) => outs.borrow_mut()[tag] = Some(r.out),
                            Err(e) => errs.borrow_mut().push(e.to_string()),
                        },
                    );
                }
            }
            planner.flush(
                &mut batched,
                &shards,
                |res, tag| match res {
                    Ok(r) => outs.borrow_mut()[tag] = Some(r.out),
                    Err(e) => errs.borrow_mut().push(e.to_string()),
                },
                |res, tag| match res {
                    Ok(r) => outs.borrow_mut()[tag] = Some(r.out),
                    Err(e) => errs.borrow_mut().push(e.to_string()),
                },
            );
            if let Some(e) = errs.borrow().first() {
                return Err(format!("solve error: {e}"));
            }
            let outs = outs.into_inner();
            for (i, want) in wants.iter().enumerate() {
                match &outs[i] {
                    None => return Err(format!("request {i} never replied")),
                    Some(got) if got != want => {
                        return Err(format!(
                            "{name}: request {i}/{n_reqs} (cap {cap}) diverged from sequential"
                        ));
                    }
                    _ => {}
                }
            }
            Ok(())
        },
    );
}
