//! PJRT runtime integration: the rust coordinator executing the AOT
//! artifacts. These tests require `make artifacts` to have run; they skip
//! (with a note) when `artifacts/manifest.json` is absent so `cargo test`
//! stays green on a fresh checkout.
//!
//! The core assertion: the PJRT path computes the SAME updates as the
//! native solver (the artifacts implement the same math as
//! `solver::native`), to f32 tolerance — which is what makes the native
//! solver a valid oracle for everything else.

use apibcd::data::{shard::PartitionKind, Dataset, DatasetProfile, Partition};
use apibcd::model::Task;
use apibcd::runtime::{Arg, CacheKey, Engine};
use apibcd::solver::{LocalSolver, NativeSolver, PjrtSolver};

const DIR: &str = "artifacts";

fn artifacts_available() -> bool {
    let ok = std::path::Path::new(&format!("{DIR}/manifest.json")).exists();
    if !ok {
        eprintln!("skipping PJRT test: run `make artifacts` first");
    }
    ok
}

fn shard_for(profile: &str) -> apibcd::data::AgentData {
    let ds = Dataset::load(DatasetProfile::by_name(profile).unwrap(), "/nonexistent", 5).unwrap();
    Partition::new(&ds, 1, PartitionKind::Iid)
        .unwrap()
        .shards
        .remove(0)
}

#[test]
fn manifest_loads_and_covers_all_profiles() {
    if !artifacts_available() {
        return;
    }
    let engine = Engine::open(DIR).unwrap();
    for profile in ["test_ls", "test_logit", "test_smax", "cpusmall", "cadata", "ijcnn1", "usps"] {
        assert!(
            engine.manifest().entry(profile, "prox").is_some(),
            "missing prox for {profile}"
        );
        assert!(
            engine.manifest().entry(profile, "grad").is_some(),
            "missing grad for {profile}"
        );
    }
}

#[test]
fn pjrt_matches_native_ls_prox_and_grad() {
    if !artifacts_available() {
        return;
    }
    let shard = shard_for("test_ls");
    let p = shard.features;
    let mut pjrt = PjrtSolver::new(DIR, "test_ls", Task::Regression).unwrap();
    let mut native = NativeSolver::new(Task::Regression, pjrt.inner_k);

    let w0: Vec<f32> = (0..p).map(|j| 0.1 * j as f32 - 0.2).collect();
    let tzsum: Vec<f32> = (0..p).map(|j| 0.05 * j as f32).collect();
    for tau_m in [0.2f32, 1.0, 4.0] {
        let a = pjrt.prox(&shard, &w0, &tzsum, tau_m).unwrap().w;
        let b = native.prox(&shard, &w0, &tzsum, tau_m).unwrap().w;
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 2e-4, "prox τM={tau_m}: {x} vs {y}");
        }
    }
    let a = pjrt.grad(&shard, &w0).unwrap().w;
    let b = native.grad(&shard, &w0).unwrap().w;
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 2e-4, "grad: {x} vs {y}");
    }
}

#[test]
fn pjrt_matches_native_logit() {
    if !artifacts_available() {
        return;
    }
    let shard = shard_for("test_logit");
    let p = shard.features;
    let mut pjrt = PjrtSolver::new(DIR, "test_logit", Task::Binary).unwrap();
    let mut native = NativeSolver::new(Task::Binary, pjrt.inner_k);
    let w0 = vec![0.1f32; p];
    let tzsum = vec![0.02f32; p];
    let a = pjrt.prox(&shard, &w0, &tzsum, 0.5).unwrap().w;
    let b = native.prox(&shard, &w0, &tzsum, 0.5).unwrap().w;
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 2e-4, "logit prox: {x} vs {y}");
    }
}

#[test]
fn pjrt_matches_native_smax() {
    if !artifacts_available() {
        return;
    }
    let shard = shard_for("test_smax");
    let dim = shard.features * shard.classes;
    let mut pjrt = PjrtSolver::new(DIR, "test_smax", Task::Multiclass(3)).unwrap();
    let mut native = NativeSolver::new(Task::Multiclass(3), pjrt.inner_k);
    let w0: Vec<f32> = (0..dim).map(|j| 0.01 * (j % 7) as f32).collect();
    let tzsum = vec![0.0f32; dim];
    let a = pjrt.prox(&shard, &w0, &tzsum, 1.0).unwrap().w;
    let b = native.prox(&shard, &w0, &tzsum, 1.0).unwrap().w;
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 5e-4, "smax prox: {x} vs {y}");
    }
    let a = pjrt.grad(&shard, &w0).unwrap().w;
    let b = native.grad(&shard, &w0).unwrap().w;
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 5e-4, "smax grad: {x} vs {y}");
    }
}

#[test]
fn engine_validates_shapes() {
    if !artifacts_available() {
        return;
    }
    let mut engine = Engine::open(DIR).unwrap();
    let entry = engine.manifest().entry("test_ls", "grad").unwrap().clone();
    // Wrong arity.
    let err = engine.execute(&entry.name, &[]);
    assert!(err.is_err());
    // Wrong shape.
    let bad = vec![0.0f32; 4];
    let err = engine.execute(
        &entry.name,
        &[
            Arg::Host(&bad, &[2, 2]),
            Arg::Host(&bad, &[4]),
            Arg::Host(&bad, &[4]),
            Arg::Host(&bad, &[4]),
        ],
    );
    assert!(err.is_err(), "shape mismatch must be rejected");
    // Unknown entry.
    assert!(engine.execute("nope", &[]).is_err());
    // Cache miss.
    let err = engine.execute(
        &entry.name,
        &[
            Arg::Cached(CacheKey { agent: 99, slot: 0 }),
            Arg::Host(&bad, &[4]),
            Arg::Host(&bad, &[4]),
            Arg::Host(&bad, &[4]),
        ],
    );
    assert!(err.is_err(), "cache miss must be rejected");
}

#[test]
fn engine_caches_buffers_and_counts_executions() {
    if !artifacts_available() {
        return;
    }
    let shard = shard_for("test_ls");
    let mut engine = Engine::open(DIR).unwrap();
    let entry = engine.manifest().entry("test_ls", "grad").unwrap().clone();
    let key = CacheKey { agent: 0, slot: 0 };
    engine
        .cache_buffer(key, &shard.x, &[shard.rows, shard.features])
        .unwrap();
    assert!(engine.has_cached(key));
    // Re-cache is a no-op.
    engine
        .cache_buffer(key, &shard.x, &[shard.rows, shard.features])
        .unwrap();

    let w = vec![0.0f32; shard.features];
    for _ in 0..3 {
        let out = engine
            .execute(
                &entry.name,
                &[
                    Arg::Cached(key),
                    Arg::Host(&shard.y, &[shard.rows]),
                    Arg::Host(&shard.mask, &[shard.rows]),
                    Arg::Host(&w, &[shard.features]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), shard.features);
    }
    assert_eq!(engine.stats.executions, 3);
    assert!(engine.stats.execute_secs > 0.0);
}

#[test]
fn full_experiment_on_pjrt_solver() {
    if !artifacts_available() {
        return;
    }
    use apibcd::algo::AlgoKind;
    use apibcd::config::{ExperimentConfig, Preset, SolverChoice};
    let mut cfg = ExperimentConfig::preset(Preset::TestLs);
    cfg.solver = SolverChoice::Pjrt;
    cfg.algos = vec![AlgoKind::IBcd, AlgoKind::ApiBcd];
    cfg.stop.max_activations = 300;
    cfg.tau_api = 0.1;
    let report = apibcd::run_experiment(&cfg).unwrap();
    for t in &report.traces {
        assert!(t.last_metric() < 0.3, "{}: {}", t.name, t.last_metric());
    }

    // And the PJRT run must match the native run exactly on the metric
    // (same math, same order of operations at f32 → identical floats is too
    // strong across backends; require tight agreement instead).
    cfg.solver = SolverChoice::Native;
    let native = apibcd::run_experiment(&cfg).unwrap();
    for (tp, tn) in report.traces.iter().zip(&native.traces) {
        assert!(
            (tp.last_metric() - tn.last_metric()).abs() < 1e-3,
            "{}: pjrt {} vs native {}",
            tp.name,
            tp.last_metric(),
            tn.last_metric()
        );
    }
}
