//! State-machine property tests: drive the *real* scheduler primitives
//! through randomized operation sequences and compare against trivially
//! correct reference models (PR-8 tentpole, part b).
//!
//! Where the loom suite (`tests/loom_runtime.rs`) exhaustively checks
//! *interleavings* of tiny scenarios, these properties check *long
//! histories*: hundreds of randomized enqueue/claim/steal/stop sequences
//! per case, asserting
//!
//! * single ownership — the claim bit admits one runner at a time and the
//!   run queue never holds an entry for an unclaimed agent (no phantom
//!   wakeup);
//! * no lost message — every delivered message is served, retired by the
//!   stop drain, or swept at shutdown, exactly once;
//! * exact totals — the `Relaxed` event counters the runtimes use for stop
//!   rules and metrics reconcile exactly against the reference count after
//!   the pool joins (the satellite-3 ordering audit, executed);
//! * wheel ≡ BTreeMap — the `TimerWheel` fires the same multiset of items
//!   as an ordered-map reference at every advance: never early, exactly
//!   once, across slot-0/revolution boundaries.
//!
//! Deep tier: `PROPTEST_CASES=4096 cargo test --test statemachine` (see
//! EXPERIMENTS.md §Verification).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use apibcd::engine::claim::MailSlot;
use apibcd::scenario::executor::StealQueue;
use apibcd::sim::{Arrival, EventQueue, TimerWheel};
use apibcd::util::proptest::{run_prop, PropConfig};

fn cfg(cases: usize, seed: u64) -> PropConfig {
    PropConfig { cases, seed }
}

/// One randomized scheduler op for the sequential reference-model check.
#[derive(Debug, Clone, Copy)]
enum Op {
    /// Deliver the next message id to agent `usize`.
    Deliver(usize),
    /// Pop one run-queue entry and run the `run_claimed` skeleton once.
    Run,
}

/// Trivially correct single-threaded scheduler: explicit inboxes, a
/// scheduled bit, and a FIFO run queue.
struct RefSched {
    inbox: Vec<VecDeque<u32>>,
    scheduled: Vec<bool>,
    runq: VecDeque<usize>,
    served: Vec<(usize, u32)>,
}

impl RefSched {
    fn new(agents: usize) -> RefSched {
        RefSched {
            inbox: vec![VecDeque::new(); agents],
            scheduled: vec![false; agents],
            runq: VecDeque::new(),
            served: Vec::new(),
        }
    }

    fn deliver(&mut self, a: usize, msg: u32) {
        self.inbox[a].push_back(msg);
        if !self.scheduled[a] {
            self.scheduled[a] = true;
            self.runq.push_back(a);
        }
    }

    fn run_one(&mut self) -> Option<usize> {
        let a = self.runq.pop_front()?;
        assert!(self.scheduled[a], "reference model broke its own invariant");
        if let Some(msg) = self.inbox[a].pop_front() {
            self.served.push((a, msg));
        }
        if self.inbox[a].is_empty() {
            self.scheduled[a] = false;
        } else {
            self.runq.push_back(a);
        }
        Some(a)
    }
}

/// Sequential refinement: `MailSlot` + a 1-shard `StealQueue` (FIFO, so
/// histories are comparable) produce *exactly* the reference model's serve
/// sequence, claim states, and queue occupancy at every step of a random
/// deliver/run history.
#[test]
fn prop_mailslot_scheduler_refines_reference_model() {
    run_prop(
        "mailslot scheduler ≡ reference model",
        cfg(96, 0x5EED_0801),
        |r| {
            let agents = 1 + r.below(5);
            let ops: Vec<Op> = (0..20 + r.below(60))
                .map(|_| {
                    if r.below(2) == 0 {
                        Op::Deliver(r.below(agents))
                    } else {
                        Op::Run
                    }
                })
                .collect();
            (agents, ops)
        },
        |&(agents, ref ops)| {
            let slots: Vec<MailSlot<u32>> = (0..agents).map(|_| MailSlot::new()).collect();
            let q: StealQueue<usize> = StealQueue::new(1);
            let mut model = RefSched::new(agents);
            let mut served: Vec<(usize, u32)> = Vec::new();
            let mut next_msg = 0u32;

            let mut step = |slots: &[MailSlot<u32>],
                            q: &StealQueue<usize>,
                            model: &mut RefSched,
                            served: &mut Vec<(usize, u32)>,
                            op: Op|
             -> Result<(), String> {
                match op {
                    Op::Deliver(a) => {
                        if slots[a].deliver(next_msg) {
                            q.push(a, a);
                        }
                        model.deliver(a, next_msg);
                        next_msg += 1;
                    }
                    Op::Run => {
                        let real = q.try_pop(0);
                        let reference = model.run_one();
                        if real != reference {
                            return Err(format!("popped {real:?}, model popped {reference:?}"));
                        }
                        if let Some(a) = real {
                            if !slots[a].is_claimed() {
                                return Err(format!("phantom wakeup: entry for unclaimed {a}"));
                            }
                            if let Some(msg) = slots[a].take() {
                                served.push((a, msg));
                            }
                            if slots[a].has_mail() {
                                q.push(a, a);
                            } else if slots[a].release() {
                                q.push(a, a);
                            }
                        }
                    }
                }
                // Claim bits must track the model's scheduled bits exactly.
                for a in 0..slots.len() {
                    if slots[a].is_claimed() != model.scheduled[a] {
                        return Err(format!(
                            "agent {a}: claimed={} but model scheduled={}",
                            slots[a].is_claimed(),
                            model.scheduled[a]
                        ));
                    }
                }
                Ok(())
            };

            for &op in ops {
                step(&slots, &q, &mut model, &mut served, op)?;
            }
            // Flush: run until both sides quiesce, then compare histories.
            loop {
                let before = served.len();
                step(&slots, &q, &mut model, &mut served, Op::Run)?;
                if before == served.len() && model.runq.is_empty() && q.try_pop(0).is_none() {
                    break;
                }
            }
            if served != model.served {
                return Err(format!(
                    "serve history diverged:\n  real:  {served:?}\n  model: {:?}",
                    model.served
                ));
            }
            let leftovers: usize = slots.iter().map(|s| s.sweep().len()).sum();
            if leftovers != 0 {
                return Err(format!("{leftovers} messages stranded after quiesce"));
            }
            Ok(())
        },
    );
}

/// The worker-side `run_claimed` skeleton shared by the contention props:
/// claim-pop loop with the stop-drain path, phantom-wakeup assertion, and
/// `Relaxed` event counters (exactly the orderings the runtimes use).
fn worker_loop(
    w: usize,
    q: &StealQueue<usize>,
    slots: &[MailSlot<u32>],
    stop: &AtomicBool,
    served: &AtomicUsize,
    retired: &AtomicUsize,
) {
    while let Some(i) = q.pop(w) {
        assert!(slots[i].is_claimed(), "phantom wakeup: entry without a claim");
        if stop.load(Ordering::SeqCst) {
            retired.fetch_add(slots[i].drain_and_release().len(), Ordering::Relaxed);
            continue;
        }
        if slots[i].take().is_some() {
            served.fetch_add(1, Ordering::Relaxed);
        }
        if slots[i].has_mail() {
            q.push(i, i);
        } else if slots[i].release() {
            q.push(i, i);
        }
    }
}

/// Satellite 3, executed: under real contention (threads, stealing,
/// parking) the `Relaxed` fetch_add counters reconcile *exactly* against
/// the delivered total once the pool joins — modification order makes RMWs
/// exact; `Relaxed` only weakens cross-location visibility, which the join
/// edge restores.
#[test]
fn prop_contended_relaxed_counters_reconcile_exactly() {
    run_prop(
        "contended serve totals are exact",
        cfg(12, 0x5EED_0802),
        |r| {
            let agents = 2 + r.below(5);
            let workers = 2 + r.below(3);
            let msgs = 1 + r.below(48);
            let dests: Vec<usize> = (0..msgs).map(|_| r.below(agents)).collect();
            (agents, workers, dests)
        },
        |&(agents, workers, ref dests)| {
            let slots: Arc<Vec<MailSlot<u32>>> =
                Arc::new((0..agents).map(|_| MailSlot::new()).collect());
            let q: Arc<StealQueue<usize>> = Arc::new(StealQueue::new(workers));
            let stop = AtomicBool::new(false); // never tripped here
            let served = AtomicUsize::new(0);
            let retired = AtomicUsize::new(0);

            let timed_out = std::thread::scope(|scope| {
                for w in 0..workers {
                    let slots = Arc::clone(&slots);
                    let q = Arc::clone(&q);
                    let (stop, served, retired) = (&stop, &served, &retired);
                    scope.spawn(move || worker_loop(w, &q, &slots, stop, served, retired));
                }
                for (m, &dest) in dests.iter().enumerate() {
                    if slots[dest].deliver(m as u32) {
                        q.push(dest, dest);
                    }
                }
                // Quiesce, then drain-and-park the pool. Bounded: a
                // stranded message is exactly the bug this hunts, and it
                // must fail the case, not hang the CI job — close before
                // reporting so the parked workers can exit the scope.
                let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
                let mut timed_out = false;
                while served.load(Ordering::Relaxed) < dests.len() {
                    if std::time::Instant::now() >= deadline {
                        timed_out = true;
                        break;
                    }
                    std::thread::yield_now();
                }
                q.close();
                timed_out
            });
            if timed_out {
                return Err(format!(
                    "lost message: served {} of {} after 20s",
                    served.load(Ordering::Relaxed),
                    dests.len()
                ));
            }

            // Post-join reads (read class b): exact by the join edge.
            if served.load(Ordering::Relaxed) != dests.len() {
                return Err(format!(
                    "served {} != delivered {}",
                    served.load(Ordering::Relaxed),
                    dests.len()
                ));
            }
            if retired.load(Ordering::Relaxed) != 0 {
                return Err("retired without a stop".into());
            }
            for (a, slot) in slots.iter().enumerate() {
                if slot.is_claimed() {
                    return Err(format!("agent {a} still claimed after quiesce"));
                }
                if slot.has_mail() {
                    return Err(format!("agent {a} has unserved mail after quiesce"));
                }
            }
            if !q.drain().is_empty() {
                return Err("run queue not empty after quiesce".into());
            }
            Ok(())
        },
    );
}

/// Stop-flag vs in-flight tokens at scale: trip the stop barrier at a
/// random point *during* delivery and check conservation — every message
/// is served, retired by a worker's stop-drain, or swept by the owner;
/// the three tallies partition the delivered total exactly.
#[test]
fn prop_stop_drain_conserves_every_message() {
    run_prop(
        "stop/drain conserves messages",
        cfg(12, 0x5EED_0803),
        |r| {
            let agents = 2 + r.below(5);
            let workers = 2 + r.below(3);
            let msgs = 1 + r.below(48);
            let stop_after = r.below(msgs + 1);
            let dests: Vec<usize> = (0..msgs).map(|_| r.below(agents)).collect();
            (agents, workers, stop_after, dests)
        },
        |&(agents, workers, stop_after, ref dests)| {
            let slots: Arc<Vec<MailSlot<u32>>> =
                Arc::new((0..agents).map(|_| MailSlot::new()).collect());
            let q: Arc<StealQueue<usize>> = Arc::new(StealQueue::new(workers));
            let stop = AtomicBool::new(false);
            let served = AtomicUsize::new(0);
            let retired = AtomicUsize::new(0);

            std::thread::scope(|scope| {
                for w in 0..workers {
                    let slots = Arc::clone(&slots);
                    let q = Arc::clone(&q);
                    let (stop, served, retired) = (&stop, &served, &retired);
                    scope.spawn(move || worker_loop(w, &q, &slots, stop, served, retired));
                }
                for (m, &dest) in dests.iter().enumerate() {
                    if m == stop_after {
                        stop.store(true, Ordering::SeqCst);
                    }
                    // Deliveries keep racing the stop, as in the runtimes.
                    if slots[dest].deliver(m as u32) {
                        q.push(dest, dest);
                    }
                }
                if stop_after >= dests.len() {
                    stop.store(true, Ordering::SeqCst);
                }
                q.close();
            });

            let _ = q.drain();
            let swept: usize = slots.iter().map(|s| s.sweep().len()).sum();
            let total =
                served.load(Ordering::Relaxed) + retired.load(Ordering::Relaxed) + swept;
            if total != dests.len() {
                return Err(format!(
                    "conservation broke: served {} + retired {} + swept {swept} != {}",
                    served.load(Ordering::Relaxed),
                    retired.load(Ordering::Relaxed),
                    dests.len()
                ));
            }
            Ok(())
        },
    );
}

/// `TimerWheel` vs an ordered-map reference over random schedule/advance
/// histories: at every advance the wheel fires exactly the reference's due
/// multiset (never early, never lost, exactly once), including stale
/// deadlines (clamped to the cursor), slot-0 wraps, and advances spanning
/// multiple revolutions.
#[test]
fn prop_timer_wheel_refines_btreemap() {
    run_prop(
        "timer wheel ≡ BTreeMap reference",
        cfg(96, 0x5EED_0804),
        |r| {
            let nslots = 1 + r.below(8);
            let horizon = 4 * nslots as u64 + 2;
            let ops: Vec<(bool, u64)> = (0..10 + r.below(50))
                .map(|_| (r.below(3) < 2, r.below(horizon as usize) as u64))
                .collect();
            (nslots, ops)
        },
        |&(nslots, ref ops)| {
            let mut wheel: TimerWheel<u32> = TimerWheel::new(0.5, nslots);
            let mut reference: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
            let mut cursor = 0u64; // mirrors the wheel's private cursor
            let mut next_id = 0u32;
            let mut scheduled = 0usize;
            let mut fired_total = 0usize;

            for &(is_schedule, t) in ops {
                if is_schedule {
                    wheel.schedule_at(t, next_id);
                    reference.entry(t.max(cursor)).or_default().push(next_id);
                    next_id += 1;
                    scheduled += 1;
                } else {
                    let mut fired = Vec::new();
                    wheel.advance_to(t, &mut fired);
                    let mut expected = Vec::new();
                    if t >= cursor {
                        let later = reference.split_off(&(t + 1));
                        expected.extend(reference.values().flatten().copied());
                        reference = later;
                        cursor = t + 1;
                    }
                    // Same-tick firing order is unspecified: compare
                    // multisets.
                    fired.sort_unstable();
                    expected.sort_unstable();
                    if fired != expected {
                        return Err(format!(
                            "advance_to({t}): fired {fired:?}, expected {expected:?}"
                        ));
                    }
                    fired_total += fired.len();
                }
                let ref_len: usize = reference.values().map(Vec::len).sum();
                if wheel.len() != ref_len {
                    return Err(format!("len {} != reference {ref_len}", wheel.len()));
                }
            }
            // Exactly-once accounting closes the books.
            let mut left = Vec::new();
            wheel.drain(&mut left);
            if fired_total + left.len() != scheduled {
                return Err(format!(
                    "accounting: fired {fired_total} + drained {} != scheduled {scheduled}",
                    left.len()
                ));
            }
            Ok(())
        },
    );
}

/// Calendar `EventQueue` vs a `BinaryHeap` reference (PR-9 tentpole): over
/// random interleaved push/pop histories the calendar queue pops *exactly*
/// the heap's (time, seq) order — including duplicate times, where only the
/// push-sequence tie-break decides, and time scales spanning nine orders of
/// magnitude so events cross the overflow level, bucket migration, and the
/// adaptive grow/shrink rebuilds. `Arrival::Ord` is the min-first ordering
/// the pre-calendar heap used, so `BinaryHeap<Arrival>` *is* the old queue.
#[test]
fn prop_calendar_queue_refines_binary_heap() {
    use std::collections::BinaryHeap;

    #[derive(Debug, Clone, Copy)]
    enum QOp {
        /// Push at `now + dt` (dt = 0 forces an exact-duplicate time).
        Push(f64),
        Pop,
    }

    run_prop(
        "calendar event queue ≡ BinaryHeap reference",
        cfg(96, 0x5EED_0901),
        |r| {
            let ops: Vec<QOp> = (0..30 + r.below(200))
                .map(|_| {
                    if r.below(5) < 3 {
                        // Mixed scales: ~µs steps (in-window), exact
                        // duplicates, and rare ×1e4 outliers (overflow).
                        let dt = match r.below(8) {
                            0 => 0.0,
                            1..=5 => r.next_f64() * 1e-4,
                            6 => r.next_f64() * 1e-1,
                            _ => r.next_f64() * 1e3,
                        };
                        QOp::Push(dt)
                    } else {
                        QOp::Pop
                    }
                })
                .collect();
            ops
        },
        |ops| {
            let mut q = EventQueue::new();
            let mut heap: BinaryHeap<Arrival> = BinaryHeap::new();
            let mut now = 0.0f64;
            let mut seq = 0u64; // mirrors the queue's private push counter
            let mut dup_time = 0.0f64;

            let check_pop = |q: &mut EventQueue,
                                 heap: &mut BinaryHeap<Arrival>,
                                 now: &mut f64|
             -> Result<(), String> {
                let real = q.pop();
                let reference = heap.pop();
                if real != reference {
                    return Err(format!("popped {real:?}, heap popped {reference:?}"));
                }
                if let Some(a) = real {
                    *now = a.time;
                }
                Ok(())
            };

            for &op in ops {
                match op {
                    QOp::Push(dt) => {
                        // dt = 0 replays the previous push's exact time, so
                        // only the seq tie-break can order the pair.
                        let t = if dt == 0.0 { dup_time } else { now + dt };
                        dup_time = t;
                        q.push(t, seq as usize % 8, seq as usize % 64);
                        heap.push(Arrival {
                            time: t,
                            seq,
                            token: seq as usize % 8,
                            agent: seq as usize % 64,
                        });
                        seq += 1;
                    }
                    QOp::Pop => check_pop(&mut q, &mut heap, &mut now)?,
                }
                if q.len() != heap.len() {
                    return Err(format!("len {} != reference {}", q.len(), heap.len()));
                }
                if q.is_empty() != heap.is_empty() {
                    return Err("is_empty disagrees with reference".into());
                }
            }
            // Drain both sides: the tails must agree event-for-event too.
            while !heap.is_empty() {
                check_pop(&mut q, &mut heap, &mut now)?;
            }
            if q.pop().is_some() {
                return Err("queue still had events after the reference drained".into());
            }
            Ok(())
        },
    );
}
