#!/usr/bin/env python3
"""Compare a bench JSON against a committed baseline and fail on regression.

Both files are the repo's bench schema (``BENCH_*.json``: a ``suite`` string
and a ``results`` list of row objects with a ``name`` and per-row metrics).
Rows are matched by ``name``; for every pair present in both files the
current metric must stay within ``--max-ratio`` of the baseline value.

When the baseline file does not exist the script exits 0 with a note — the
first run of a new suite has nothing to compare against, and CI should not
go red for that. Commit the produced JSON under ``baselines/`` to arm the
check.

Usage:
    bench_trend.py <baseline.json> <current.json>
        [--metric ns_per_activation] [--max-ratio 1.5]
"""

import argparse
import json
import os
import sys


def load_rows(path, metric):
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for r in doc.get("results", []):
        name = r.get("name")
        if name is None or metric not in r:
            continue
        value = r[metric]
        if isinstance(value, (int, float)) and value > 0:
            rows[name] = float(value)
    return doc.get("suite", "?"), rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    ap.add_argument("current", help="freshly produced BENCH_*.json")
    ap.add_argument("--metric", default="ns_per_activation",
                    help="per-row metric to compare (default: ns_per_activation)")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    help="fail when current/baseline exceeds this (default: 1.5)")
    args = ap.parse_args()

    if not os.path.exists(args.baseline):
        print(f"bench_trend: no baseline at {args.baseline} — skipping "
              f"(commit one to arm the regression check)")
        return 0

    base_suite, base = load_rows(args.baseline, args.metric)
    cur_suite, cur = load_rows(args.current, args.metric)
    if base_suite != cur_suite:
        print(f"bench_trend: suite mismatch: baseline '{base_suite}' vs "
              f"current '{cur_suite}'", file=sys.stderr)
        return 2

    shared = sorted(set(base) & set(cur))
    if not shared:
        print(f"bench_trend: no shared rows between baseline and current "
              f"({len(base)} vs {len(cur)} rows)", file=sys.stderr)
        return 2

    failed = []
    for name in shared:
        ratio = cur[name] / base[name]
        status = "FAIL" if ratio > args.max_ratio else "ok"
        print(f"{status:<5} {name:<48} {args.metric} "
              f"{base[name]:>12.0f} -> {cur[name]:>12.0f}  ({ratio:.2f}x)")
        if ratio > args.max_ratio:
            failed.append((name, ratio))

    dropped = sorted(set(base) - set(cur))
    if dropped:
        print(f"note: {len(dropped)} baseline row(s) absent from current run: "
              f"{', '.join(dropped)}")

    if failed:
        print(f"\nbench_trend: {len(failed)} row(s) regressed beyond "
              f"{args.max_ratio}x on {args.metric}", file=sys.stderr)
        return 1
    print(f"\nbench_trend: {len(shared)} row(s) within {args.max_ratio}x "
          f"of baseline on {args.metric}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
